"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU), per the kernels/<name>/{kernel,ops,ref} contract."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.decode_attn.ops import decode_attention as decode_attn_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm
from repro.kernels.wagg import (auto_block_n, wagg, wagg_fused,
                                wagg_fused_ref, wagg_ref)


# -- wagg -------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("p,n,bn", [(2, 64, 64), (8, 1000, 256),
                                    (16, 4096, 512), (32, 333, 128)])
def test_wagg_sweep(p, n, bn, dtype):
    key = jax.random.key(p * n)
    x = jax.random.normal(key, (p, n), dtype)
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (p,)))
    for beta in (0.0, 0.5, 1.0):
        out = wagg(x, theta, beta, block_n=bn)
        ref = wagg_ref(x, theta, beta)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(p=st.integers(2, 16), n=st.integers(1, 300),
       beta=st.floats(0.0, 1.0), seed=st.integers(0, 99))
def test_hyp_wagg_arbitrary_shapes(p, n, beta, seed):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (p, n), jnp.float32)
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (p,)))
    out = wagg(x, theta, beta, block_n=128)
    np.testing.assert_allclose(out, wagg_ref(x, theta, beta),
                               rtol=1e-4, atol=1e-5)


# -- wagg v2: fused dequant + mask + Eq. 10 -----------------------------------------

def _fused_case(seed=0, p=6, n=1000):
    """p=6 (not a power of two) and n=1000 with block_n=256 (padded tail)."""
    key = jax.random.key(seed)
    x = jax.random.normal(key, (p, n), jnp.float32)
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                             (p,)))
    return x, theta


@pytest.mark.parametrize("codec_name", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("masked", [False, True])
def test_wagg_fused_codec_parity(codec_name, masked):
    """The fused kernel consuming quantized payload tiles stays within the
    codec's documented error bound of the f32 reference — the same contract
    the composition grid holds the composed backend to, here at the kernel
    level, with a padded tail and p not a power of two."""
    from repro.core.codecs import get_codec
    x, theta = _fused_case()
    p = x.shape[0]
    codec = get_codec(codec_name)
    payload, aux = codec.encode(x)
    theta_eff = theta if aux is None else theta * jnp.float32(aux)
    active = None
    beta_eff = 0.9
    if masked:
        active = jnp.asarray(np.arange(p) % 3 != 1, jnp.float32)
        beta_eff = 1.0                  # late-join rows adopt m wholesale
    out = wagg_fused(x, theta_eff, 0.9, payload=payload, active=active,
                     block_n=256)
    ref = wagg_fused_ref(x, theta, 0.9)   # f32, no payload
    if masked:
        ref = jnp.where(active[:, None] != 0, ref,
                        jnp.einsum("p,pn->n", theta, x)[None, :])
    tol = float(codec.error_bound(x, theta, beta_eff))
    err = float(jnp.abs(out - ref).max())
    assert err <= tol, (codec_name, masked, err, tol)


def test_wagg_fused_matches_its_reference():
    """wagg_fused == wagg_fused_ref exactly (same payload, same mask), on
    the padded-tail fixture."""
    from repro.core.codecs import get_codec
    x, theta = _fused_case(1)
    payload, aux = get_codec("int8").encode(x)
    theta_eff = theta * jnp.float32(aux)
    active = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])
    out = wagg_fused(x, theta_eff, 0.7, payload=payload, active=active,
                     block_n=256)
    ref = wagg_fused_ref(x, theta_eff, 0.7, payload=payload, active=active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_wagg_fused_beta_endpoints():
    """beta=0 is the identity on active rows; beta=1 makes every row the
    aggregate m (masked or not — late-join and FMA coincide)."""
    x, theta = _fused_case(2, p=5, n=333)
    out0 = wagg_fused(x, theta, 0.0, block_n=128)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(x))
    out1 = wagg_fused(x, theta, 1.0, block_n=128)
    m = np.einsum("p,pn->n", np.asarray(theta), np.asarray(x))
    for i in range(x.shape[0]):
        np.testing.assert_allclose(np.asarray(out1)[i], m, rtol=1e-5,
                                   atol=1e-6)


def test_wagg_interpret_default_tracks_backend():
    """Regression: ``interpret`` was hardcoded True, so the compiled kernel
    never ran even on a real TPU. The default must be None (resolved from
    jax.default_backend() at call time)."""
    import inspect
    assert inspect.signature(wagg).parameters["interpret"].default is None
    assert inspect.signature(wagg_fused).parameters["interpret"].default \
        is None


def test_auto_block_n_budget_guard():
    """The VMEM guard: small p keeps the requested block; a wide worker axis
    auto-shrinks block_n until the tile set fits the budget, never below
    the 128 floor."""
    assert auto_block_n(8, 8192, 8) == 8192
    bn = auto_block_n(4096, 8192, 8)
    assert bn < 8192 and bn >= 128
    assert bn * 4096 * 8 <= 8 * 1024 * 1024 or bn == 128
    assert auto_block_n(1 << 20, 8192, 8) == 128    # floor, never 0


def test_wagg_fused_shrink_path_correct():
    """p=300 at block_n=4096 overflows the 8 MiB budget (300*4096*8 ≈ 9.8
    MiB) — the guard shrinks the block and the result must still match."""
    key = jax.random.key(5)
    p, n = 300, 4096
    x = jax.random.normal(key, (p, n), jnp.float32)
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                             (p,)))
    assert auto_block_n(p, 4096, 8) < 4096
    out = wagg(x, theta, 0.9, block_n=4096)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(wagg_ref(x, theta, 0.9)),
                               rtol=1e-4, atol=1e-5)


# -- decode_attn ---------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kv,g,hd,S,bs", [
    (1, 1, 4, 64, 128, 64),
    (2, 2, 4, 32, 300, 128),
    (2, 8, 1, 128, 256, 256),   # MHA-style
    (1, 1, 8, 256, 700, 512),   # gemma-style kv=1
])
def test_decode_attn_sweep(b, kv, g, hd, S, bs, dtype):
    key = jax.random.key(b + S)
    q = jax.random.normal(key, (b, kv, g, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd), dtype)
    for cl in (1, S // 2, S):
        out = decode_attn(q, k, v, jnp.int32(cl), block_s=bs)
        ref = decode_attn_ref(q, k, v, jnp.int32(cl))
        tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


def test_decode_attn_window_sweep():
    b, kv, g, hd, S = 1, 2, 2, 32, 200
    key = jax.random.key(7)
    q = jax.random.normal(key, (b, kv, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd))
    for cl, win in [(10, 4), (150, 64), (200, 128), (200, 1)]:
        out = decode_attn(q, k, v, jnp.int32(cl), window=win, block_s=64)
        ref = decode_attn_ref(q, k, v, jnp.int32(cl), window=win)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_decode_attn_model_layout_wrapper():
    b, h, kv, hd, S = 2, 8, 2, 32, 96
    key = jax.random.key(9)
    q = jax.random.normal(key, (b, 1, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd))
    out = decode_attn_op(q, k, v, jnp.int32(50))
    from repro.models.attention import decode_attention as model_ref
    ref = model_ref(q, k, v, jnp.int32(50))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# -- paged decode_attn ---------------------------------------------------------------

def _paged_setup(b, kv, g, hd, bs, nblk, seed):
    """Random pools + a shuffled block table (+ trash row at the end)."""
    from numpy.random import default_rng
    rng = default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
    n_pool = b * nblk + 1
    kp = jnp.asarray(rng.normal(size=(n_pool, bs, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pool, bs, kv, hd)), jnp.float32)
    tab = jnp.asarray(rng.permutation(b * nblk).reshape(b, nblk), jnp.int32)
    return q, kp, vp, tab


@pytest.mark.parametrize("b,kv,g,hd,bs,nblk", [
    (2, 1, 4, 32, 16, 4),
    (3, 2, 2, 16, 8, 6),
])
def test_paged_decode_attn_kernel_vs_ref(b, kv, g, hd, bs, nblk):
    from repro.kernels.decode_attn import (paged_decode_attn,
                                           paged_decode_attn_ref)
    q, kp, vp, tab = _paged_setup(b, kv, g, hd, bs, nblk, seed=b)
    S = bs * nblk
    idx = jnp.asarray([(7 * i + 3) % S for i in range(b)], jnp.int32)
    for ring, window in [(None, None), (S, None), (S, S // 3)]:
        out = paged_decode_attn(q, kp, vp, tab, idx, ring=ring,
                                window=window, interpret=True)
        ref = paged_decode_attn_ref(q, kp, vp, tab, idx, ring=ring,
                                    window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


def test_paged_linear_matches_dense_oracle():
    """Linear layout: gathering the table must reproduce dense attention
    over the first cache_len positions."""
    from repro.kernels.decode_attn import paged_decode_attn_ref
    b, kv, g, hd, bs, nblk = 2, 2, 2, 16, 8, 4
    q, kp, vp, tab = _paged_setup(b, kv, g, hd, bs, nblk, seed=3)
    S = bs * nblk
    idx = jnp.asarray([5, 25], jnp.int32)
    out = paged_decode_attn_ref(q, kp, vp, tab, idx)
    k_lin = kp[tab].reshape(b, S, kv, hd)
    v_lin = vp[tab].reshape(b, S, kv, hd)
    for i in range(b):
        ref = decode_attn_ref(q[i:i + 1], k_lin[i:i + 1], v_lin[i:i + 1],
                              jnp.int32(int(idx[i]) + 1))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0]),
                                   rtol=3e-5, atol=3e-5)


def test_paged_ring_wraparound_matches_dense_oracle():
    """Ring layout past the wrap point: a logically-linear K/V stream laid
    onto the ring (slot = p % R) must attend over exactly the last
    ``window`` positions, matching dense attention on the compacted tail."""
    from repro.kernels.decode_attn import (paged_decode_attn,
                                           paged_decode_attn_ref)
    b, kv, g, hd, bs, nblk, window = 1, 1, 2, 16, 8, 3, 20
    R = bs * nblk                                          # 24 >= window
    key = jax.random.key(12)
    L = 61                                                 # wraps twice
    q = jax.random.normal(key, (b, kv, g, hd))
    k_seq = jax.random.normal(jax.random.fold_in(key, 1), (L, kv, hd))
    v_seq = jax.random.normal(jax.random.fold_in(key, 2), (L, kv, hd))

    kp = jnp.zeros((nblk + 1, bs, kv, hd))
    vp = jnp.zeros((nblk + 1, bs, kv, hd))
    tab = jnp.arange(nblk, dtype=jnp.int32)[None]
    for p in range(L):                    # stream tokens through the ring
        slot = p % R
        kp = kp.at[slot // bs, slot % bs].set(k_seq[p])
        vp = vp.at[slot // bs, slot % bs].set(v_seq[p])
    idx = jnp.asarray([L - 1], jnp.int32)

    # dense oracle over the last `window` tokens, compacted
    tail_k = k_seq[None, L - window:]
    tail_v = v_seq[None, L - window:]
    ref = decode_attn_ref(q, tail_k, tail_v, jnp.int32(window))

    for impl in (paged_decode_attn_ref,
                 lambda *a, **kw: paged_decode_attn(*a, interpret=True,
                                                    **kw)):
        out = impl(q, kp, vp, tab, idx, ring=R, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


# -- rmsnorm -------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,br", [((8, 64), 4), ((3, 5, 128), 8),
                                      ((1000, 96), 256)])
def test_rmsnorm_sweep(shape, br, dtype):
    key = jax.random.key(shape[-1])
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],), jnp.float32)
    out = rmsnorm(x, s, block_rows=br)
    ref = rmsnorm_ref(x, s)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_wagg_leaf_tree_integration():
    """The kernel-backed aggregate equals the einsum aggregate on a tree."""
    from repro.core import weighted_aggregate, equal_weights
    from repro.kernels.wagg.ops import wagg_leaf
    params = {"a": jax.random.normal(jax.random.key(0), (4, 3, 5)),
              "b": jax.random.normal(jax.random.key(1), (4, 7))}
    axes = {"a": ("worker", None, None), "b": ("worker", None)}
    th = equal_weights(4)
    ref = weighted_aggregate(params, axes, th, 0.8)
    out = weighted_aggregate(params, axes, th, 0.8, leaf_fn=wagg_leaf)
    for k in params:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


# -- fused_ce -------------------------------------------------------------------------

from repro.kernels.fused_ce import fused_ce, fused_ce_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,v,br,bv", [(16, 64, 8, 32), (100, 500, 32, 128),
                                       (256, 1000, 64, 256)])
def test_fused_ce_sweep(t, v, br, bv, dtype):
    key = jax.random.key(t + v)
    logits = jax.random.normal(key, (t, v), dtype) * 4
    labels = jax.random.randint(jax.random.fold_in(key, 1), (t,), 0, v)
    out = fused_ce(logits, labels, block_rows=br, block_v=bv)
    ref = fused_ce_ref(logits, labels)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 60), v=st.integers(2, 300), seed=st.integers(0, 50))
def test_hyp_fused_ce_arbitrary(t, v, seed):
    key = jax.random.key(seed)
    logits = jax.random.normal(key, (t, v), jnp.float32) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (t,), 0, v)
    out = fused_ce(logits, labels, block_rows=16, block_v=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(fused_ce_ref(logits, labels)),
                               rtol=1e-4, atol=1e-4)


# -- ssd_chunk ------------------------------------------------------------------------

from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref, ssd_chunked_kernel
from repro.models.ssm import ssd_reference


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nc,L,nh,hd,ds", [
    (1, 2, 8, 2, 4, 3),
    (2, 3, 16, 4, 8, 5),
    (1, 4, 64, 2, 64, 128),   # mamba2-370m-shaped chunk
])
def test_ssd_chunk_sweep(b, nc, L, nh, hd, ds, dtype):
    key = jax.random.key(b * L + ds)
    xs = jax.random.normal(key, (b, nc, L, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, nc, L, nh))).astype(dtype)
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, nc, L, ds), dtype)
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, nc, L, ds), dtype)
    y, st, tot = ssd_chunk(xs, dt, a, B, C)
    yr, sr, tr = ssd_chunk_ref(xs, dt, a, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(tr), rtol=1e-4,
                               atol=1e-4)


def test_ssd_chunked_kernel_full_pipeline():
    """Kernel-backed chunked SSD == naive per-step recurrence end to end."""
    key = jax.random.key(11)
    b, s, nh, hd, ds, chunk = 2, 48, 3, 8, 5, 16
    xs = jax.random.normal(key, (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, nh)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, ds))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, ds))
    yk, stk = ssd_chunked_kernel(xs, dt, a, B, C, chunk=chunk)
    yn, stn = ssd_reference(xs, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yn), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(stn), rtol=1e-3,
                               atol=1e-3)
