"""The paper's theoretical claims, verified numerically:

* Theorem 1  — beta=1 WASGD+ iterates contract (exponential convergence on a
               convex quadratic).
* Lemma 2    — asymptotic variance of the weighted aggregate matches Eq. 35.
* Lemma 3    — equally weighted case with zeta=1 IS mini-batch SGD.
* Property 2 — a->inf weighting underperforms the equal baseline; a->0
               approaches it.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, WASGDConfig
from repro.core.weights import boltzmann_weights, equal_weights, omega
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer


# ---------------------------------------------------------------------------
# Theorem 1: contraction / exponential convergence
# ---------------------------------------------------------------------------

def test_theorem1_exponential_convergence():
    """WASGD (beta=1) on a noisy convex quadratic: log-error decays linearly."""
    p, d, eta, tau = 4, 8, 0.1, 5
    key = jax.random.key(0)
    x_star = jax.random.normal(key, (d,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (p, d)) * 5.0

    errs = []
    for t in range(40):
        for k in range(tau):
            g = (x - x_star[None])   # exact gradient of 0.5||x - x*||^2
            noise = 0.01 * jax.random.normal(jax.random.fold_in(key, t * 97 + k),
                                             (p, d))
            x = x - eta * (g + noise)
        h = 0.5 * jnp.sum((x - x_star) ** 2, axis=-1)
        th = boltzmann_weights(h, 1.0)
        x = jnp.broadcast_to((th[:, None] * x).sum(0), x.shape)  # beta = 1
        errs.append(float(jnp.linalg.norm(x[0] - x_star)))

    errs = np.array(errs)
    assert errs[-1] < 1e-2
    # exponential rate: each round shrinks the error by a constant factor
    early = np.log(errs[2] / errs[7])
    assert early > 0.5, f"no contraction: errs={errs[:8]}"


# ---------------------------------------------------------------------------
# Lemma 2: asymptotic variance (Eq. 35)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("theta_kind", ["equal", "skewed"])
def test_lemma2_asymptotic_variance(theta_kind):
    p, c, eta, zeta = 4, 1.0, 0.1, 0.3
    sb, sh = 0.3, 1.0
    chains = 20000
    T = 400
    key = jax.random.key(42)

    if theta_kind == "equal":
        theta = np.full(p, 1.0 / p)
    else:
        theta = np.array([0.4, 0.3, 0.2, 0.1])
    om = float((theta ** 2).sum())
    rho = 2 * c * eta - (eta * c) ** 2
    delta = zeta / ((1 - zeta) * eta * (2 * c - eta * c ** 2))
    predicted = (eta * sh ** 2 * om /
                 (2 * c - eta * c ** 2 - eta * sb ** 2 * (1 + delta * om)
                  / (1 + delta)))

    x = jnp.zeros((chains, p))
    th = jnp.asarray(theta, jnp.float32)

    def step(x, key):
        kb, kh, kc = jax.random.split(key, 3)
        b = sb * jax.random.normal(kb, x.shape)
        h = sh * jax.random.normal(kh, x.shape)
        x = (1 - eta * c) * x + eta * (b * x + h)
        comm = jax.random.uniform(kc, (chains, 1)) < zeta
        agg = (x * th[None]).sum(-1, keepdims=True)
        x = jnp.where(comm, agg, x)
        return x, None

    keys = jax.random.split(key, T)
    x, _ = jax.lax.scan(step, x, keys)
    q = float(jnp.mean(jnp.square((x * th[None]).sum(-1))))
    assert abs(q - predicted) / predicted < 0.15, (q, predicted)


# ---------------------------------------------------------------------------
# Lemma 3: equal weights + zeta=1 == mini-batch SGD
# ---------------------------------------------------------------------------

def test_lemma3_minibatch_equivalence():
    p, b_local, d, eta = 4, 2, 16, 0.05
    key = jax.random.key(0)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=d, d_hidden=32, n_classes=3), key)

    X = np.asarray(jax.random.normal(jax.random.fold_in(key, 1), (p * b_local, d)))
    y = np.asarray(jax.random.randint(jax.random.fold_in(key, 2),
                                      (p * b_local,), 0, 3))

    def loss_fn(pr, batch):
        return cnn.classification_loss(cnn.mlp_apply(pr, batch["x"]),
                                       batch["y"]), {}

    tcfg = TrainConfig(learning_rate=eta, optimizer="sgd",
                       wasgd=WASGDConfig(tau=1, beta=1.0, strategy="equal"))
    tr = Trainer(loss_fn, params, axes, tcfg, p, rule="spsgd")
    batch = {"x": jnp.asarray(X), "y": jnp.asarray(y)}
    state, _ = tr._step(tr.state, batch)
    wasgd_params = jax.tree.map(lambda v: v[0], state.params)

    # manual mini-batch SGD step over the same p*b_local samples
    grads = jax.grad(lambda pr: loss_fn(pr, batch)[0])(params)
    manual = jax.tree.map(lambda pv, g: pv - eta * g, params, grads)

    for a, b in zip(jax.tree.leaves(wasgd_params), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Property 2: extreme a_tilde behavior
# ---------------------------------------------------------------------------

def test_property2_extremes():
    """Weighted-case distance to the equal baseline: a->0 approaches it,
    a->inf concentrates to one worker (the sequential-like regime)."""
    h = jnp.array([1.0, 1.1, 1.3, 2.0])
    base = equal_weights(4)
    near = boltzmann_weights(h, 1e-6)
    far = boltzmann_weights(h, 1e5)
    assert float(jnp.abs(near - base).sum()) < 1e-4
    assert float(omega(far)) > 0.99  # all mass on one worker
