"""Weighted aggregation (Eq. 10) semantics + worker-tree plumbing."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (aggregate_leaf, equal_weights, replicate_workers,
                        take_worker, weighted_aggregate, worker_in_axes)
from repro.core.aggregate import strip_worker_axis


def _tree(p=4):
    params = {"a": {"w": jnp.arange(p * 6, dtype=jnp.float32).reshape(p, 2, 3)},
              "experts": {"w_up": jnp.ones((2, 3))}}
    axes = {"a": {"w": ("worker", None, None)},
            "experts": {"w_up": ("experts", None)}}
    return params, axes


def test_beta1_equal_is_mean():
    params, axes = _tree()
    th = equal_weights(4)
    out = weighted_aggregate(params, axes, th, beta=1.0)
    mean = params["a"]["w"].mean(0)
    for i in range(4):
        np.testing.assert_allclose(out["a"]["w"][i], mean, rtol=1e-6)


def test_beta0_identity():
    params, axes = _tree()
    th = jnp.array([0.1, 0.2, 0.3, 0.4])
    out = weighted_aggregate(params, axes, th, beta=0.0)
    np.testing.assert_allclose(out["a"]["w"], params["a"]["w"])


def test_expert_leaves_untouched():
    params, axes = _tree()
    out = weighted_aggregate(params, axes, equal_weights(4), beta=1.0)
    np.testing.assert_allclose(out["experts"]["w_up"],
                               params["experts"]["w_up"])


def test_eq10_matches_manual():
    x = jnp.array([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])
    th = jnp.array([0.5, 0.3, 0.2])
    beta = 0.7
    agg = (th[:, None] * x).sum(0)
    expected = (1 - beta) * x + beta * agg[None]
    np.testing.assert_allclose(aggregate_leaf(x, th, beta), expected,
                               rtol=1e-6)


def test_quantized_aggregation_close():
    x = jax.random.normal(jax.random.key(0), (4, 256))
    th = jax.nn.softmax(jnp.arange(4.0))
    exact = aggregate_leaf(x, th, 0.9)
    quant = aggregate_leaf(x, th, 0.9, quantize=True)
    err = np.abs(np.asarray(exact - quant)).max()
    assert err < 0.05  # int8 with per-leaf scale: ~x.max()/127 * beta


def test_replicate_and_take_worker():
    single = {"a": {"w": jnp.ones((2, 3))},
              "moe": {"experts": {"w_up": jnp.ones((4, 2))}}}
    axes = {"a": {"w": (None, None)},
            "moe": {"experts": {"w_up": ("experts", None)}}}
    stacked, st_axes = replicate_workers(single, axes, 3)
    assert stacked["a"]["w"].shape == (3, 2, 3)
    assert stacked["moe"]["experts"]["w_up"].shape == (4, 2)  # shared
    assert st_axes["a"]["w"][0] == "worker"
    back = take_worker(stacked, st_axes, 1)
    np.testing.assert_allclose(back["a"]["w"], single["a"]["w"])


def test_worker_in_axes_and_strip():
    in_ax = worker_in_axes(_tree()[1])
    assert in_ax["a"]["w"] == 0
    assert in_ax["experts"]["w_up"] is None
    stripped = strip_worker_axis(_tree()[1])
    assert stripped["a"]["w"] == (None, None)


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(2, 8),
    beta=st.floats(0.0, 1.0),
    seed=st.integers(0, 1000),
)
def test_hyp_aggregate_preserves_weighted_mean(p, beta, seed):
    """The theta-weighted mean is a fixed point of Eq. 10."""
    key = jax.random.key(seed)
    x = jax.random.normal(key, (p, 5))
    th = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (p,)))
    out = aggregate_leaf(x, th, beta)
    np.testing.assert_allclose((th[:, None] * out).sum(0),
                               (th[:, None] * x).sum(0), rtol=1e-4, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(p=st.integers(2, 6), seed=st.integers(0, 100))
def test_hyp_beta1_collapses_all_workers(p, seed):
    """beta = 1: all workers coincide after one communication (Sec. 4.1)."""
    x = jax.random.normal(jax.random.key(seed), (p, 7))
    th = jax.nn.softmax(jax.random.normal(jax.random.key(seed + 1), (p,)))
    out = np.asarray(aggregate_leaf(x, th, 1.0))
    for i in range(1, p):
        np.testing.assert_allclose(out[i], out[0], rtol=1e-5, atol=1e-6)
