import os
import sys

# Make `repro` importable without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests prefer the real `hypothesis` (pip install -e .[test]); on
# bare images fall back to the deterministic shim so the suite still collects
# and exercises a sampled subset of each property.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    _hypothesis_fallback.install()

# Tests must see the real single CPU device — never the dry-run's 512
# placeholders (the dry-run sets its own XLA_FLAGS before any import).
