import os
import sys

# Make `repro` importable without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the real single CPU device — never the dry-run's 512
# placeholders (the dry-run sets its own XLA_FLAGS before any import).
