"""Serving demo: continuous batching on a paged KV cache.

Part 1 submits a ragged mix of requests (different prompt positions,
budgets, temperatures) to `ContinuousEngine` — more requests than slots, so
the scheduler inserts and evicts at token boundaries while the paged cache
recycles blocks. Part 2 hot-swaps the engine's params mid-generation, the
way `Trainer.run(serve_hook=)` pushes fresh consensus weights into a live
engine. Part 3 keeps the legacy monolithic `ServeEngine` for the media
archs (cross-attention / codebook heads) the paged engine does not serve.

    PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import init_params
from repro.serve import ContinuousEngine, HotSwapBridge, ServeEngine


def _cfg(arch):
    return dataclasses.replace(get_smoke_config(arch),
                               compute_dtype="float32")


def main():
    # --- continuous batching across cache regimes -------------------------
    for arch in ["yi-6b", "gemma3-1b", "mamba2-370m"]:
        cfg = _cfg(arch)
        params, _ = init_params(cfg, jax.random.key(0))
        engine = ContinuousEngine(cfg, params, n_slots=2, max_len=128,
                                  block_size=16, cache_dtype=jnp.float32,
                                  chunk=8)
        prompts = np.asarray(lm_batch(0, 5, 16, cfg.vocab_size)["tokens"])
        budgets = [4, 24, 9, 16, 2]          # ragged: finish at odd times
        rids = [engine.submit(prompts[i], budgets[i],
                              temperature=0.0 if i % 2 == 0 else 0.8,
                              seed=i) for i in range(5)]
        done = engine.run()
        kind = ("SSM state" if cfg.ssm is not None else
                f"window={cfg.attn_window}" if cfg.attn_window else "full KV")
        lens = [len(done[r]) for r in rids]
        print(f"{arch:14s} [{kind:12s}] 5 requests on 2 slots, "
              f"lens={lens} head={done[rids[1]][:6].tolist()}")
        assert lens == budgets and engine.scheduler.idle

    # --- live hot-swap: params change mid-flight, request survives --------
    cfg = _cfg("gemma3-1b")
    params, _ = init_params(cfg, jax.random.key(1))
    engine = ContinuousEngine(cfg, params, n_slots=2, max_len=128,
                              block_size=16, cache_dtype=jnp.float32,
                              chunk=8)
    bridge = HotSwapBridge(engine)
    prompt = np.asarray(lm_batch(1, 1, 16, cfg.vocab_size)["tokens"])[0]
    rid = engine.submit(prompt, n_new=32)
    engine.step()                                     # decode one chunk
    fresh = jax.tree.map(lambda p: p * 0.999, params)  # "newly trained"
    engine.swap_params(fresh)
    out = engine.run()[rid]
    print(f"hot-swap        request survived the swap: {len(out)} tokens, "
          f"{engine.n_swaps} swap(s)")
    assert len(out) == 32

    # --- media archs stay on the legacy monolithic engine -----------------
    for arch in ["llama-3.2-vision-11b", "musicgen-large"]:
        cfg = _cfg(arch)
        params, _ = init_params(cfg, jax.random.key(2))
        legacy = ServeEngine(cfg, params, max_len=64,
                             cache_dtype=jnp.float32)
        batch = lm_batch(2, 2, 8, cfg.vocab_size,
                         n_codebooks=cfg.n_codebooks,
                         media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
        media = (np.asarray(batch["media"], np.float32)
                 if "media" in batch else None)
        out = legacy.generate(np.asarray(batch["tokens"]), n_new=6,
                              media=media)
        print(f"{arch:20s} [legacy engine] out shape={out.shape}")
    print("serving demo OK")


if __name__ == "__main__":
    main()
