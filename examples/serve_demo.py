"""Serving demo: batched prefill + token-by-token decode with KV caches.

Covers three cache regimes: full-attention KV (yi), sliding-window ring
buffers (gemma3), and O(1) SSM recurrent state (mamba2).

    PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    for arch in ["yi-6b", "gemma3-1b", "mamba2-370m"]:
        cfg = dataclasses.replace(get_smoke_config(arch),
                                  compute_dtype="float32")
        params, _ = init_params(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params, max_len=128,
                             cache_dtype=jax.numpy.float32)

        batch = 4
        prompt = np.asarray(lm_batch(0, batch, 16, cfg.vocab_size)["tokens"])
        out_greedy = engine.generate(prompt, n_new=16, temperature=0.0)
        out_sampled = engine.generate(prompt, n_new=16, temperature=0.8,
                                      seed=1)
        kind = ("SSM state" if cfg.ssm is not None else
                f"window={cfg.attn_window}" if cfg.attn_window else "full KV")
        print(f"{arch:14s} [{kind:12s}] batch={batch} "
              f"greedy={out_greedy[0, :6].tolist()} "
              f"sampled={out_sampled[0, :6].tolist()}")
        assert out_greedy.shape == (batch, 16)
    print("serving demo OK")


if __name__ == "__main__":
    main()
