"""End-to-end training driver: a ~100M-parameter dense LM trained with
WASGD+ for a configurable number of rounds, with metrics JSONL, periodic
checkpoints, and held-out evaluation of the aggregated consensus model.

    # smoke-scale (CPU, seconds):
    PYTHONPATH=src python examples/train_e2e.py --smoke --rounds 10

    # the real thing (~100M params; run on accelerator hardware):
    PYTHONPATH=src python examples/train_e2e.py --rounds 300
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ModelConfig, TrainConfig, WASGDConfig
from repro.data import OrderedDataset, make_tokens
from repro.models import init_params
from repro.train import Trainer
from repro.train.evaluate import consensus_params, evaluate_lm
from repro.train.lm import make_lm_loss


def model_100m() -> ModelConfig:
    """~100M dense decoder (12L x 640, vocab 32k)."""
    return ModelConfig(
        name="wasgd-100m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv_heads=10, head_dim=64, d_ff=2560, vocab_size=32000,
        compute_dtype="float32", remat=False,
        source="examples/train_e2e.py (paper-scale driver)")


def model_smoke() -> ModelConfig:
    return dataclasses.replace(model_100m(), name="wasgd-e2e-smoke",
                               n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, head_dim=32, d_ff=512,
                               vocab_size=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--b-local", type=int, default=4)
    ap.add_argument("--metrics", default="/tmp/wasgd_e2e_metrics.jsonl")
    ap.add_argument("--ckpt", default="/tmp/wasgd_e2e_ckpt")
    args = ap.parse_args()

    cfg = model_smoke() if args.smoke else model_100m()
    print(f"model={cfg.name} params={cfg.param_count():,} "
          f"workers={args.workers} tau={args.tau}")

    toks = make_tokens(0, 4096, args.seq, cfg.vocab_size)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ds = OrderedDataset(data, args.workers, args.tau, args.b_local,
                        n_segments=2)
    params, axes = init_params(cfg, jax.random.key(0))
    tcfg = TrainConfig(learning_rate=0.02, optimizer="sgd",
                       wasgd=WASGDConfig(tau=args.tau, beta=0.9, a_tilde=1.0))
    trainer = Trainer(make_lm_loss(cfg), params, axes, tcfg, args.workers)
    summary = trainer.run(
        ds.batches(), args.rounds, order_state=ds.order,
        segment_fn=ds.segment_of_round,
        log_every=max(1, args.rounds // 10),
        metrics_path=args.metrics,
        checkpoint_every=max(1, args.rounds // 2),
        checkpoint_path=args.ckpt)
    print(f"train: {summary}")

    # evaluate the served consensus copy on held-out data
    served = consensus_params(trainer.state.params, trainer.axes)
    held = make_tokens(999, 256, args.seq, cfg.vocab_size)

    def eval_batches():
        i = 0
        while True:
            sl = held[(i * 16) % 240:(i * 16) % 240 + 16]
            yield {"tokens": sl[:, :-1], "labels": sl[:, 1:]}
            i += 1

    metrics = evaluate_lm(cfg, served, eval_batches(), n_batches=4)
    print(f"held-out: {metrics}")


if __name__ == "__main__":
    main()
