"""Quickstart: train a small transformer LM with WASGD+ (4 workers) on CPU.

    PYTHONPATH=src python examples/quickstart.py

Shows the full public API path: config -> init -> Trainer(rule="wasgd") ->
order-managed data pipeline -> checkpoint save/restore.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import TrainConfig, WASGDConfig, get_smoke_config
from repro.data import OrderedDataset, make_tokens
from repro.models import init_params
from repro.train import Trainer
from repro.train.lm import make_lm_loss


def main():
    cfg = get_smoke_config("stablelm-1.6b")
    print(f"model: {cfg.name}  params={cfg.param_count():,}")

    p_workers, tau, b_local, seq = 4, 4, 2, 64
    tcfg = TrainConfig(
        learning_rate=0.03, optimizer="sgd",
        wasgd=WASGDConfig(tau=tau, beta=0.9, a_tilde=1.0,
                          strategy="boltzmann"))

    # synthetic bigram language (offline container) — tokens/labels pairs
    toks = make_tokens(0, 2048, seq, cfg.vocab_size)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ds = OrderedDataset(data, p_workers, tau, b_local, n_segments=2)

    params, axes = init_params(cfg, jax.random.key(0))
    trainer = Trainer(make_lm_loss(cfg), params, axes, tcfg, p_workers,
                      rule="wasgd")
    trainer.run(ds.batches(), n_rounds=20, order_state=ds.order,
                segment_fn=ds.segment_of_round, log_every=5)

    losses = trainer.losses()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(theta of last round: {np.round(trainer.history[-1]['theta'], 3)})")
    assert losses[-1] < losses[0], "training should reduce loss"

    save("/tmp/wasgd_quickstart_ckpt", trainer.state.params,
         meta={"rounds": 20, "arch": cfg.name})
    restored, meta = restore("/tmp/wasgd_quickstart_ckpt",
                             jax.tree.map(jnp.zeros_like, trainer.state.params))
    print(f"checkpoint round-trip OK (meta={meta})")


if __name__ == "__main__":
    main()
