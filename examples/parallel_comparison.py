"""Benchmark-style comparison of all seven parallel SGD methods from the
paper (Sec. 5.2.2) on synthetic classification — a CPU-scale rendition of
Figure 8.

    PYTHONPATH=src python examples/parallel_comparison.py
"""
import functools

import jax
import numpy as np

from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset, make_classification
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer

METHODS = [
    ("SGD (sequential)", "seq", {}),
    ("SPSGD", "spsgd", {}),
    ("EASGD", "easgd", {}),
    ("OMWU", "omwu", {}),
    ("MMWU", "mmwu", {}),
    ("WASGD (1/h)", "wasgd", dict(strategy="inverse", beta=1.0)),
    ("WASGD+ (Boltzmann)", "wasgd", dict(strategy="boltzmann", beta=0.9,
                                         a_tilde=1.0)),
    # same rule through a different aggregation backend (core/backends.py) —
    # WASGDConfig.backend selects it end-to-end through the train step.
    ("WASGD+ (int8 comm)", "wasgd", dict(strategy="boltzmann", beta=0.9,
                                         a_tilde=1.0, backend="quantized")),
]


def main():
    X, y = make_classification(0, 8192, d=64, n_classes=10, noise=0.25)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=64, d_hidden=128, n_classes=10), jax.random.key(0))

    def loss_fn(p, batch):
        return cnn.classification_loss(cnn.mlp_apply(p, batch["x"]),
                                       batch["y"]), {}

    p_workers, tau, rounds = 4, 8, 25
    print(f"{'method':24s} {'first':>8s} {'final':>8s}")
    results = {}
    for label, rule, kw in METHODS:
        tcfg = TrainConfig(learning_rate=0.05,
                           wasgd=WASGDConfig(tau=tau, **kw))
        ds = OrderedDataset({"x": X, "y": y}, p_workers, tau, 8,
                            n_segments=2, seed=7)
        tr = Trainer(loss_fn, params, axes, tcfg, p_workers, rule=rule)
        use_order = label.endswith("+ (Boltzmann)")
        tr.run(ds.batches(), rounds,
               order_state=ds.order if use_order else None,
               segment_fn=ds.segment_of_round if use_order else None)
        losses = tr.losses()
        results[label] = losses[-1]
        print(f"{label:24s} {losses[0]:8.4f} {losses[-1]:8.4f}")

    best = min(results, key=results.get)
    print(f"\nbest: {best}")


if __name__ == "__main__":
    main()
